"""Pure-JAX visual control suite + RL substrate smoke tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.envs import pendulum
from repro.envs.wrappers import PixelEnv, make_pixel_env
from repro.rl.buffers import ReplayBuffer
from repro.rl.networks import make_encoder

TASKS = ["pendulum", "hopper", "walker"]


@pytest.mark.parametrize("task", TASKS)
def test_pixel_env_obs_contract(task):
    """Paper's wrapper stack: 3-frame stack of 84x84 crops (channel-last
    here; VecTransposeImage is a layout detail), float32 in [0,1]."""
    env = make_pixel_env(task)
    key = jax.random.PRNGKey(0)
    state, obs = env.reset(key)
    assert obs.shape == (84, 84, 9)
    assert obs.dtype == jnp.float32
    assert float(obs.min()) >= 0.0 and float(obs.max()) <= 1.0
    action = jnp.zeros((env.action_dim,))
    state, obs2, reward, done = env.step(state, action)
    assert obs2.shape == (84, 84, 9)
    assert jnp.isfinite(reward)


def test_pendulum_dynamics_exact():
    """Classic-control Pendulum ODE matches gym's closed form."""
    s = pendulum.PendulumState(theta=jnp.asarray(0.1),
                               theta_dot=jnp.asarray(0.0),
                               t=jnp.zeros((), jnp.int32))
    s2, reward, done = pendulum.step(s, jnp.asarray([0.25]))
    g, m, l, dt = 10.0, 1.0, 1.0, 0.05
    u = 0.25 * 2.0   # action scaled by MAX_TORQUE
    expected_thdot = (3 * g / (2 * l) * np.sin(0.1)
                      + 3.0 / (m * l ** 2) * u) * dt
    assert float(s2.theta_dot) == pytest.approx(expected_thdot, rel=1e-5)
    expected_cost = 0.1 ** 2 + 0.001 * u ** 2
    assert float(-reward) == pytest.approx(expected_cost, rel=1e-5)
    assert not bool(done)


def test_rgba_uint8_boundary():
    env = make_pixel_env("pendulum")
    _, obs = env.reset(jax.random.PRNGKey(0))
    rgba = PixelEnv.to_rgba_uint8(obs)
    assert rgba.dtype == jnp.uint8
    assert rgba.shape == (84, 84, 12)       # 3 frames x RGBA
    alpha = rgba.reshape(84, 84, 3, 4)[..., 3]
    assert int(alpha.min()) == 255          # opaque alpha per the paper


def test_train_vs_eval_crop():
    """Random crop during training, deterministic centre crop at eval."""
    key = jax.random.PRNGKey(0)
    _, o1 = make_pixel_env("pendulum", train=False).reset(key)
    _, o2 = make_pixel_env("pendulum", train=False).reset(key)
    np.testing.assert_array_equal(o1, o2)


def test_batched_env_helpers_match_single():
    """reset_batch/step_batch (the engines' vectorised API) agree with the
    per-env reset/step on every env of the batch."""
    env = make_pixel_env("pendulum")
    keys = jax.random.split(jax.random.PRNGKey(0), 3)
    states, obs = env.reset_batch(keys)
    assert obs.shape == (3, 84, 84, 9)
    _, o1 = env.reset(keys[1])
    np.testing.assert_array_equal(np.asarray(obs[1]), np.asarray(o1))
    actions = jnp.zeros((3, env.action_dim))
    states2, obs2, reward, done = env.step_batch(states, actions)
    assert obs2.shape == (3, 84, 84, 9)
    assert reward.shape == (3,) and done.shape == (3,)
    s1 = jax.tree.map(lambda x: x[1], states)
    _, o, r, d = env.step(s1, actions[1])
    np.testing.assert_array_equal(np.asarray(obs2[1]), np.asarray(o))
    assert float(reward[1]) == pytest.approx(float(r))


@pytest.mark.parametrize("name", ["miniconv4", "miniconv16", "full_cnn"])
def test_encoders(name):
    enc = make_encoder(name, c_in=9)
    key = jax.random.PRNGKey(0)
    params = enc.init(key)
    obs = jax.random.uniform(key, (2, 84, 84, 9))
    feats = enc.apply(params, obs)
    assert feats.ndim == 2 and feats.shape[0] == 2
    assert not jnp.isnan(feats).any()


def test_miniconv_encoder_respects_shader_budget():
    enc = make_encoder("miniconv16", c_in=9)
    assert enc.spec is not None
    enc.spec.validate()   # raises if any pass violates the paper budget


def test_replay_buffer_roundtrip():
    buf = ReplayBuffer(100, (84, 84, 9), 1)
    obs = np.random.rand(4, 84, 84, 9).astype(np.float32)
    buf.add_batch(obs, np.zeros((4, 1), np.float32),
                  np.ones((4,), np.float32), obs, np.zeros((4,), bool))
    assert len(buf) == 4
    batch = buf.sample(2)
    assert batch["obs"].shape == (2, 84, 84, 9)
    assert float(np.abs(batch["obs"] - obs[:1]).max()) <= 1.0
    # uint8 quantisation in storage: error bounded by 1/255
    idx = np.argmin(np.abs(batch["rewards"] - 1.0))
    assert batch["rewards"][idx] == 1.0


@pytest.mark.slow
def test_rl_training_smoke():
    """A short DDPG run on pendulum with the MiniConv encoder records at
    least one episode per parallel env — 256 steps over the default
    ``n_envs`` cannot finish a 200-step pendulum episode, so these are the
    explicitly-counted end-of-training truncations (full runs live in
    benchmarks/learning.py)."""
    from repro.rl.train import train
    res = train("pendulum", "miniconv4", total_steps=256)
    assert res.summary()["episodes"] >= 1
    assert len(res.all_returns) >= 1
    assert np.isfinite(res.mean)
    assert res.env_steps >= 256
