"""Autotuner + streaming-backend conformance suite.

Covers the ISSUE-6 tentpole: tuner determinism under stubbed
timing/measurement, TunedPlan manifest round-trips (including pre-tuning
manifests), cost-model pruning never excluding the modelled optimum on the
seed spec grid, the ``fused+stream`` parity suite (B in {1, max_safe,
max_safe+1, 4*max_safe} x odd/even X x head on/off), and
``Deployment.build`` pipelining over-budget batches instead of rejecting
them.
"""
import dataclasses
import itertools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.backends import backend_names, get_backend
from repro.core.miniconv import miniconv_apply, miniconv_init, standard_spec
from repro.core.tuning import (Candidate, TunedPlan, baseline_candidate,
                               default_candidates, estimated_cost_s,
                               measure_candidate, prune_candidates,
                               suggest_tuning, tune, vmem_feasible)
from repro.deploy import CONFIG_VERSION, Deployment, DeploymentConfig
from repro.kernels.miniconv_pass import (miniconv_encoder,
                                         miniconv_encoder_stream)


def small_config(**overrides):
    kw = dict(k=4, c_in=12, h=12, max_batch=4)
    kw.update(overrides)
    return DeploymentConfig.standard(**kw)


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

def test_fused_stream_backend_registered():
    b = get_backend("fused+stream")
    assert b.mode == "fused" and b.streamed and b.fused_head
    assert get_backend("fused_stream") is b          # alias
    assert "fused+stream" in backend_names()
    # the established backends are not streamed
    for name in ("xla", "reference", "grouped", "fused", "fused+head"):
        assert not get_backend(name).streamed


# ---------------------------------------------------------------------------
# TunedPlan serialisation
# ---------------------------------------------------------------------------

def make_tuned(**overrides):
    kw = dict(backend="fused+head", tile_h=4, micro_batch=3, time_s=1.5e-3,
              per_frame_s=4e-4, mode="interpret", host="linux/x86_64/cpu/2",
              searched=7, pruned=11)
    kw.update(overrides)
    return TunedPlan(**kw)


def test_tunedplan_roundtrip():
    tp = make_tuned()
    assert TunedPlan.from_dict(tp.to_dict()) == tp


def test_tunedplan_rejects_unknown_fields_and_versions():
    tp = make_tuned()
    with pytest.raises(ValueError, match="unknown TunedPlan"):
        TunedPlan.from_dict({**tp.to_dict(), "wat": 1})
    with pytest.raises(ValueError, match="version"):
        TunedPlan.from_dict({**tp.to_dict(), "version": 99})


def test_manifest_roundtrip_with_tuning():
    cfg = dataclasses.replace(small_config(), tuning=make_tuned())
    d = cfg.to_dict()
    assert d["version"] == CONFIG_VERSION
    assert d["tuning"]["backend"] == "fused+head"
    cfg2 = DeploymentConfig.from_json(cfg.to_json())
    assert cfg2 == cfg and cfg2.tuning == cfg.tuning


def test_pre_tuning_manifest_defaults_cleanly():
    """A version-1 manifest (no tuning key) loads with tuning=None."""
    d = small_config().to_dict()
    del d["tuning"]
    d["version"] = 1
    cfg = DeploymentConfig.from_dict(d)
    assert cfg.tuning is None
    assert Deployment.build(cfg).backend.name == cfg.backend


def test_tuning_validated():
    cfg = dataclasses.replace(small_config(),
                              tuning=make_tuned(micro_batch=0))
    with pytest.raises(ValueError, match="micro_batch"):
        cfg.validate()
    with pytest.raises(ValueError, match="backend"):
        dataclasses.replace(small_config(),
                            tuning=make_tuned(backend="nope")).validate()


# ---------------------------------------------------------------------------
# Build honours the frozen TunedPlan
# ---------------------------------------------------------------------------

def test_build_resolves_tuning():
    cfg = dataclasses.replace(small_config(backend="fused"),
                              tuning=make_tuned(backend="fused+head",
                                                tile_h=2))
    dep = Deployment.build(cfg)
    assert dep.backend.name == "fused+head"
    assert dep.tile_h == 2
    assert any("tuning" in line for line in dep.build_log)
    # untouched config still resolves its own backend
    dep0 = Deployment.build(small_config(backend="fused"))
    assert dep0.backend.name == "fused" and dep0.build_log == ()


def test_tuned_streamed_backend_matches_fused(seed=0):
    """fused+stream via a frozen TunedPlan == fused+head, bitwise, at a
    batch divisible by the tuned micro-batch."""
    base = small_config(backend="fused+head", head_placement="fused")
    tuned = dataclasses.replace(
        base, tuning=make_tuned(backend="fused+stream", tile_h=2,
                                micro_batch=3))
    dep_f = Deployment.build(base)
    dep_s = Deployment.build(tuned)
    assert dep_s.stream_chunk == 3
    params = dep_f.init(jax.random.PRNGKey(seed))
    obs = jax.random.uniform(jax.random.PRNGKey(seed + 1), (12, 12, 12, 12))
    np.testing.assert_array_equal(dep_f.encoder.apply(params, obs),
                                  dep_s.encoder.apply(params, obs))


# ---------------------------------------------------------------------------
# Pruning / cost model
# ---------------------------------------------------------------------------

def test_pruning_never_excludes_modelled_optimum_on_seed_grid():
    """On the seed spec grid (standard k=4 c_in=12 at the paper's X=84
    and smaller), the candidate the cost model itself ranks best is never
    pruned — so measuring the pruned grid finds the modelled optimum."""
    for h, mb in ((12, 4), (48, 4), (84, 8)):
        cfg = DeploymentConfig.standard(k=4, c_in=12, h=h, max_batch=mb)
        cands = default_candidates(cfg)
        kept, n_pruned = prune_candidates(cfg, cands)
        feasible = [c for c in cands if vmem_feasible(cfg, c)]
        opt = min(feasible, key=lambda c: estimated_cost_s(cfg, c))
        assert opt in kept, (h, opt)
        assert baseline_candidate(cfg) in kept
        assert n_pruned > 0, "cost model pruned nothing"


def test_pruning_drops_vmem_infeasible_compiled_candidates():
    cfg = small_config(interpret=False)
    plan = cfg.spec.plan(cfg.in_h, cfg.in_w)
    safe = plan.max_safe_batch(tile_h=2)
    over = Candidate(backend="fused", tile_h=2, micro_batch=safe + 1)
    assert not vmem_feasible(cfg, over, compiled=True)
    # streamed backend only needs ONE frame to fit
    streamed = Candidate(backend="fused+stream", tile_h=2,
                         micro_batch=safe + 1)
    assert vmem_feasible(cfg, streamed, compiled=True)
    kept, _ = prune_candidates(cfg, [over, streamed,
                                     baseline_candidate(cfg)],
                               compiled=True)
    assert over not in kept and streamed in kept


def test_suggest_tuning_is_feasible_and_deterministic():
    cfg = small_config()
    s1, s2 = suggest_tuning(cfg), suggest_tuning(cfg)
    assert s1 == s2
    assert vmem_feasible(cfg, s1)
    assert s1.micro_batch <= cfg.max_batch


# ---------------------------------------------------------------------------
# Tuner determinism
# ---------------------------------------------------------------------------

def test_tune_deterministic_under_measure_stub():
    cfg = small_config()
    stub = lambda c, cand: estimated_cost_s(c, cand)
    t1 = tune(cfg, measure=stub)
    t2 = tune(cfg, measure=stub)
    assert t1 == t2
    assert t1.searched > 0 and t1.pruned > 0
    assert t1.mode == "interpret"
    assert vmem_feasible(cfg, Candidate(t1.backend, t1.tile_h,
                                        t1.micro_batch))


def test_tune_deterministic_under_timer_stub():
    """With a fixed fake timer, the REAL measurement path (builds the
    deployment, runs the kernel) returns identical medians, so two tunes
    pick the identical winner."""
    cfg = small_config(max_batch=2)
    cands = [Candidate("xla", 2, 2), Candidate("fused", 2, 2),
             Candidate("fused+head", 2, 2)]

    def make_timer():
        t = itertools.count()
        return lambda: float(next(t))

    t1 = tune(cfg, candidates=cands, iters=3, timer=make_timer())
    t2 = tune(cfg, candidates=cands, iters=3, timer=make_timer())
    assert t1 == t2
    assert t1.backend in {c.backend for c in cands}


def test_measure_candidate_runs_live_kernel():
    cfg = small_config(max_batch=2)
    t = measure_candidate(cfg, Candidate("fused", 2, 2), iters=2)
    assert t > 0.0


# ---------------------------------------------------------------------------
# Streaming parity suite
# ---------------------------------------------------------------------------

def _stream_fixture(x_size, with_head, seed=0):
    spec = standard_spec()
    params = miniconv_init(jax.random.PRNGKey(seed), spec)
    plan = spec.plan(x_size)
    ws = [params[f"layer{i}"]["kernel"] for i in range(len(spec.layers))]
    bs = [params[f"layer{i}"]["bias"] for i in range(len(spec.layers))]
    hw = hb = None
    if with_head:
        hw = jax.random.normal(jax.random.PRNGKey(seed + 1),
                               (plan.flat_features, 20)) * 0.05
        hb = jax.random.normal(jax.random.PRNGKey(seed + 2), (20,)) * 0.05
    return plan, ws, bs, hw, hb


def _assert_pair_equal(got, want):
    if isinstance(want, tuple):
        np.testing.assert_array_equal(got[0], want[0])
        np.testing.assert_array_equal(got[1], want[1])
    else:
        np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("x_size", [11, 12])        # odd / even X
@pytest.mark.parametrize("with_head", [False, True])
def test_stream_parity_across_vmem_boundary(x_size, with_head):
    """B in {1, max_safe, max_safe+1, 4*max_safe} under a synthetic VMEM
    budget: the multi-launch path is bitwise-equal to chunk-by-chunk
    fused calls, and at whole-chunk batches the pipelined grid is bitwise
    equal to both."""
    plan, ws, bs, hw, hb = _stream_fixture(x_size, with_head)
    head = plan.head(20) if with_head else None
    # synthetic budget: exactly 3 frames fit -> max_safe = 3
    limit = plan.vmem_bytes(3, head=head)
    max_safe = plan.max_safe_batch(head=head, vmem_limit=limit)
    assert max_safe == 3

    def fused(xb):
        return miniconv_encoder(xb, ws, bs, plan, head_w=hw, head_b=hb)

    def chunked(xb):
        outs = [fused(xb[i:i + max_safe])
                for i in range(0, xb.shape[0], max_safe)]
        if with_head:
            return (jnp.concatenate([o[0] for o in outs]),
                    jnp.concatenate([o[1] for o in outs]))
        return jnp.concatenate(outs)

    for b in (1, max_safe, max_safe + 1, 4 * max_safe):
        x = jax.random.uniform(jax.random.PRNGKey(b),
                               (b, x_size, x_size, 12))
        multi = miniconv_encoder_stream(x, ws, bs, plan, chunk_b=max_safe,
                                        head_w=hw, head_b=hb,
                                        pipelined=False)
        _assert_pair_equal(multi, chunked(x))
        if b % max_safe == 0:
            pipe = miniconv_encoder_stream(x, ws, bs, plan,
                                           chunk_b=max_safe, head_w=hw,
                                           head_b=hb, pipelined=True)
            _assert_pair_equal(pipe, chunked(x))
            _assert_pair_equal(pipe, multi)


def test_stream_pipelined_matches_whole_batch_launch():
    """The chunk-grid pipelined kernel is bitwise-equal to the single
    whole-batch fused launch, ragged remainder included."""
    plan, ws, bs, hw, hb = _stream_fixture(12, True)
    x = jax.random.uniform(jax.random.PRNGKey(3), (13, 12, 12, 12))
    whole = miniconv_encoder(x, ws, bs, plan, head_w=hw, head_b=hb)
    pipe = miniconv_encoder_stream(x, ws, bs, plan, chunk_b=3, head_w=hw,
                                   head_b=hb, pipelined=True)
    _assert_pair_equal(pipe, whole)


def test_stream_chunk_ge_batch_short_circuits():
    plan, ws, bs, hw, hb = _stream_fixture(12, False)
    x = jax.random.uniform(jax.random.PRNGKey(4), (2, 12, 12, 12))
    out = miniconv_encoder_stream(x, ws, bs, plan, chunk_b=8)
    np.testing.assert_array_equal(out, miniconv_encoder(x, ws, bs, plan))
    with pytest.raises(ValueError, match="chunk_b"):
        miniconv_encoder_stream(x, ws, bs, plan, chunk_b=0)


def test_miniconv_apply_stream_chunk_param():
    """miniconv_apply's stream_chunk splits any fused call; the
    fused+stream backend picks the plan's safe chunk automatically."""
    spec = standard_spec()
    params = miniconv_init(jax.random.PRNGKey(0), spec)
    x = jax.random.uniform(jax.random.PRNGKey(1), (7, 12, 12, 12))
    ref = miniconv_apply(params, spec, x, use_kernel="fused")
    np.testing.assert_array_equal(
        miniconv_apply(params, spec, x, use_kernel="fused", stream_chunk=7),
        ref)
    np.testing.assert_array_equal(
        miniconv_apply(params, spec, x, use_kernel="fused+stream"), ref)


# ---------------------------------------------------------------------------
# Deployment pipelines over-budget batches
# ---------------------------------------------------------------------------

def test_build_pipelines_over_budget_compiled_batch():
    """The paper-scale serving config that USED to be rejected (X=84
    fused+head, max_batch=64 > max_safe_batch) now builds, streaming the
    launch in VMEM-safe chunks, and logs the decision with the computed
    max_safe_batch and the tuner's suggestion."""
    cfg = DeploymentConfig.standard(k=4, c_in=12, h=84, backend="fused+head",
                                    interpret=False, max_batch=64)
    dep = Deployment.build(cfg)
    assert 1 <= dep.stream_chunk <= dep.max_safe_batch < 64
    note = " ".join(dep.build_log)
    assert "pipelining" in note and "max_safe_batch" in note
    assert "tile_h" in note and "micro_batch" in note   # tuner suggestion


def test_build_still_rejects_single_frame_over_vmem():
    """Pipelining cannot rescue a frame that exceeds VMEM alone: build
    still fails, reporting max_safe_batch=0 and the tuner's suggestion."""
    cfg = DeploymentConfig.standard(k=4, c_in=12, h=2048, backend="fused",
                                    interpret=False, max_batch=64)
    with pytest.raises(ValueError, match="VMEM") as ei:
        Deployment.build(cfg)
    msg = str(ei.value)
    assert "max_safe_batch=0" in msg and "suggests" in msg


def test_interpret_build_does_not_stream_plain_fused():
    """Interpret-mode plain-fused builds keep the single-launch path (no
    VMEM constraint to pipeline around)."""
    dep = Deployment.build(DeploymentConfig.standard(
        k=4, c_in=12, h=84, backend="fused+head", max_batch=64,
        interpret=True))
    assert dep.stream_chunk is None


def test_streamed_deployment_serves_past_max_safe_batch():
    """End-to-end: a fused+stream deployment encodes B = 4x its chunk in
    one call, matching the fused+head deployment bitwise."""
    base = small_config(backend="fused+head", head_placement="fused",
                        max_batch=12)
    tuned = dataclasses.replace(
        base, tuning=make_tuned(backend="fused+stream", tile_h=2,
                                micro_batch=3))
    dep_s = Deployment.build(tuned)
    dep_f = Deployment.build(base)
    params = dep_f.init(jax.random.PRNGKey(0))
    obs = jax.random.uniform(jax.random.PRNGKey(1),
                             (4 * dep_s.stream_chunk, 12, 12, 12))
    np.testing.assert_array_equal(dep_f.encoder.apply(params, obs),
                                  dep_s.encoder.apply(params, obs))
