"""Loop-aware HLO analyzer: validated against cost_analysis (loop-free)
and hand counts (scans, nested scans, collectives)."""
import jax
import jax.numpy as jnp
import pytest

from repro.launch.hlo_analysis import (analyse_hlo, flat_cost_analysis,
                                       parse_computations)


def _compiled(f, *args):
    return jax.jit(f).lower(*args).compile()


def test_loop_free_matches_cost_analysis():
    def f(x, w1, w2):
        return jax.nn.relu(x @ w1) @ w2
    c = _compiled(f, jnp.ones((128, 256)), jnp.ones((256, 512)),
                  jnp.ones((512, 64)))
    t = analyse_hlo(c.as_text())
    expected = 2 * 128 * 256 * 512 + 2 * 128 * 512 * 64
    assert abs(t.flops - expected) / expected < 0.01


def test_scan_multiplies_by_trip_count():
    def g(x, w):
        def body(x, _):
            return jnp.tanh(x @ w), None
        return jax.lax.scan(body, x, None, length=10)[0]
    c = _compiled(g, jnp.ones((64, 128)), jnp.ones((128, 128)))
    t = analyse_hlo(c.as_text())
    expected = 10 * 2 * 64 * 128 * 128
    assert abs(t.flops - expected) / expected < 0.01
    # the flat analysis underreports by ~10x — that's why we exist
    flat = flat_cost_analysis(c)["flops"]
    assert t.flops > 5 * flat


def test_nested_scans():
    def h(x, w):
        def outer(x, _):
            def inner(x, _):
                return x @ w, None
            return jax.lax.scan(inner, x, None, length=4)[0], None
        return jax.lax.scan(outer, x, None, length=3)[0]
    c = _compiled(h, jnp.ones((32, 64)), jnp.ones((64, 64)))
    t = analyse_hlo(c.as_text())
    expected = 12 * 2 * 32 * 64 * 64
    assert abs(t.flops - expected) / expected < 0.01


def test_bytes_nonzero_and_bounded():
    def f(x):
        return (x * 2 + 1).sum()
    c = _compiled(f, jnp.ones((1024, 1024)))
    t = analyse_hlo(c.as_text())
    assert t.bytes_accessed >= 4 * 1024 * 1024          # reads x once
    assert t.bytes_accessed < 40 * 4 * 1024 * 1024      # not absurd


def test_parser_handles_comments_and_tuples():
    hlo = """
HloModule m
ENTRY %main (a: (s32[], f32[4,4])) -> f32[4,4] {
  %a = (s32[], f32[4,4]{1,0}) parameter(0)
  %g = f32[4,4]{1,0} get-tuple-element(%a), index=1
  ROOT %d = f32[4,4]{1,0} dot(%g, /*index=5*/%g), lhs_contracting_dims={1}, rhs_contracting_dims={0}
}
"""
    comps, entry = parse_computations(hlo)
    assert entry == "main"
    t = analyse_hlo(hlo)
    assert t.flops == 2 * 4 * 4 * 4
