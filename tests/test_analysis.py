"""Fixture tests for repro.analysis: every rule gets at least one
positive (fires) and one negative (stays quiet) snippet, plus the
baseline machinery, the schema forward-compat contract (satellite of
rule 4), and the repo-level --strict gate."""

import json
from pathlib import Path

import pytest

from repro.analysis import (
    analyze_source,
    baseline_problems,
    diff_against_baseline,
    load_baseline,
    rule_names,
    save_baseline,
)
from repro.analysis.core import Suppression
from repro.analysis.rules_kernel import audit_vmem_budgets
from repro.analysis.rules_schema import check_registries
from repro.schema import SchemaVersionError

REPO_ROOT = Path(__file__).resolve().parent.parent


def rules_of(findings):
    return {f.rule for f in findings}


def unsuppressed(findings):
    return [f for f in findings if not f.suppressed]


# ---------------------------------------------------------------------------
# timing-warmup
# ---------------------------------------------------------------------------

TIMING_POS = """
import time
import jax

def measure(fn, x, n):
    ts = []
    for _ in range(n):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(x))
        ts.append(time.perf_counter() - t0)
    return ts
"""

TIMING_NEG = """
import time
import jax

def measure(fn, x, n):
    for _ in range(3):
        jax.block_until_ready(fn(x))
    ts = []
    for _ in range(n):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(x))
        ts.append(time.perf_counter() - t0)
    return ts
"""


def test_timing_warmup_positive():
    f = analyze_source(TIMING_POS, rules=["timing-warmup"])
    assert rules_of(f) == {"timing-warmup"}


def test_timing_warmup_negative():
    assert analyze_source(TIMING_NEG, rules=["timing-warmup"]) == []


def test_timing_warmup_block_helper_counts():
    # serving/ uses a local _block() helper instead of jax directly
    src = TIMING_NEG.replace("jax.block_until_ready", "_block")
    assert analyze_source(src, rules=["timing-warmup"]) == []


# ---------------------------------------------------------------------------
# timing-monotonic-accum
# ---------------------------------------------------------------------------

ACCUM_POS = """
import time

def run_load(period, n, send):
    t = time.monotonic()
    for _ in range(n):
        t += period
        send(t)
"""

ACCUM_NEG = """
import time

def run_load(period, n, send):
    t_start = time.monotonic()
    for i in range(n):
        send(t_start + i * period)
"""


def test_monotonic_accum_positive():
    f = analyze_source(ACCUM_POS, rules=["timing-monotonic-accum"])
    assert rules_of(f) == {"timing-monotonic-accum"}


def test_monotonic_accum_negative():
    assert analyze_source(ACCUM_NEG, rules=["timing-monotonic-accum"]) == []


# ---------------------------------------------------------------------------
# rng-reset
# ---------------------------------------------------------------------------

RNG_RESET_POS = """
import numpy as np

class Link:
    def __init__(self, seed):
        self.seed = seed
        self._rng = np.random.default_rng(seed)
        self._busy_until = 0.0

    def reset(self):
        self._busy_until = 0.0
"""

RNG_RESET_NEG = RNG_RESET_POS.replace(
    "        self._busy_until = 0.0\n",
    "        self._busy_until = 0.0\n"
    "        self._rng = np.random.default_rng(self.seed)\n",
    1,
).replace(
    "    def reset(self):\n        self._busy_until = 0.0",
    "    def reset(self):\n"
    "        self._busy_until = 0.0\n"
    "        self._rng = np.random.default_rng(self.seed)",
)


def test_rng_reset_positive():
    f = analyze_source(RNG_RESET_POS, rules=["rng-reset"])
    assert rules_of(f) == {"rng-reset"}


def test_rng_reset_negative():
    assert analyze_source(RNG_RESET_NEG, rules=["rng-reset"]) == []


# ---------------------------------------------------------------------------
# rng-unseeded (scoped to src/repro/serving/)
# ---------------------------------------------------------------------------

RNG_UNSEEDED_POS = """
import numpy as np

def jitter():
    rng = np.random.default_rng()
    return np.random.uniform(0.0, 1.0)
"""

RNG_UNSEEDED_NEG = """
import numpy as np

def jitter(seed):
    rng = np.random.default_rng(seed)
    return rng.uniform(0.0, 1.0)
"""


def test_rng_unseeded_positive():
    f = analyze_source(
        RNG_UNSEEDED_POS,
        path="src/repro/serving/fake_link.py",
        rules=["rng-unseeded"],
    )
    assert len(f) == 2 and rules_of(f) == {"rng-unseeded"}


def test_rng_unseeded_negative():
    assert (
        analyze_source(
            RNG_UNSEEDED_NEG,
            path="src/repro/serving/fake_link.py",
            rules=["rng-unseeded"],
        )
        == []
    )


def test_rng_unseeded_out_of_scope():
    # the rule only polices the seeded-simulation modules
    assert (
        analyze_source(
            RNG_UNSEEDED_POS, path="examples/demo.py", rules=["rng-unseeded"]
        )
        == []
    )


# ---------------------------------------------------------------------------
# socket-shutdown
# ---------------------------------------------------------------------------

SOCKET_POS = """
import socket

def talk(addr):
    s = socket.create_connection(addr)
    s.sendall(b"x")
    s.close()
"""

SOCKET_NEG = """
import socket

def talk(addr):
    s = socket.create_connection(addr)
    s.sendall(b"x")
    s.shutdown(socket.SHUT_RDWR)
    s.close()
"""

SOCKET_LISTENER = """
import socket

def serve():
    listener = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
    listener.bind(("127.0.0.1", 0))
    listener.listen()
    listener.close()
"""


def test_socket_shutdown_positive():
    f = analyze_source(SOCKET_POS, rules=["socket-shutdown"])
    assert rules_of(f) == {"socket-shutdown"}


def test_socket_shutdown_negative():
    assert analyze_source(SOCKET_NEG, rules=["socket-shutdown"]) == []


def test_socket_shutdown_listener_exempt():
    assert analyze_source(SOCKET_LISTENER, rules=["socket-shutdown"]) == []


# ---------------------------------------------------------------------------
# thread-lifecycle
# ---------------------------------------------------------------------------

THREAD_POS = """
import threading

def go(fn):
    t = threading.Thread(target=fn)
    t.start()
    return t
"""

THREAD_JOINED = """
import threading

def go(fn):
    t = threading.Thread(target=fn)
    t.start()
    t.join()
"""

THREAD_DAEMON = """
import threading

def go(fn):
    t = threading.Thread(target=fn, daemon=True)
    t.start()
"""

PROCESS_DAEMON = """
import multiprocessing

def go(fn):
    p = multiprocessing.Process(target=fn, daemon=True)
    p.start()
"""


def test_thread_lifecycle_positive():
    f = analyze_source(THREAD_POS, rules=["thread-lifecycle"])
    assert rules_of(f) == {"thread-lifecycle"}


def test_thread_lifecycle_joined_negative():
    assert analyze_source(THREAD_JOINED, rules=["thread-lifecycle"]) == []


def test_thread_lifecycle_daemon_thread_exempt():
    assert analyze_source(THREAD_DAEMON, rules=["thread-lifecycle"]) == []


def test_thread_lifecycle_daemon_process_not_exempt():
    # a SIGKILLed daemon process loses its sockets; it must be reaped
    f = analyze_source(PROCESS_DAEMON, rules=["thread-lifecycle"])
    assert rules_of(f) == {"thread-lifecycle"}


# ---------------------------------------------------------------------------
# schema-version
# ---------------------------------------------------------------------------

SCHEMA_POS = """
import dataclasses

@dataclasses.dataclass(frozen=True)
class Cfg:
    x: int = 1

    def to_dict(self):
        return {"x": self.x}

    @classmethod
    def from_dict(cls, d):
        return cls(**d)
"""

SCHEMA_NEG = """
import dataclasses

CFG_VERSION = 1

@dataclasses.dataclass(frozen=True)
class Cfg:
    x: int = 1

    def to_dict(self):
        return {"version": CFG_VERSION, "x": self.x}

    @classmethod
    def from_dict(cls, d):
        d = dict(d)
        version = d.pop("version", CFG_VERSION)
        if version != CFG_VERSION:
            raise ValueError(f"unsupported version {version}")
        return cls(**d)
"""


def test_schema_version_positive():
    f = analyze_source(SCHEMA_POS, rules=["schema-version"])
    assert rules_of(f) == {"schema-version"}


def test_schema_version_negative():
    assert analyze_source(SCHEMA_NEG, rules=["schema-version"]) == []


def test_schema_version_ignores_plain_classes():
    src = SCHEMA_POS.replace("@dataclasses.dataclass(frozen=True)\n", "")
    assert analyze_source(src, rules=["schema-version"]) == []


# ---------------------------------------------------------------------------
# registry-roundtrip
# ---------------------------------------------------------------------------

REGISTRY_POS = """
from repro.serving.fleet import register_router

register_router("definitely-not-a-registered-router", lambda *a: 0)
"""

REGISTRY_NEG = """
from repro.serving.fleet import register_router

register_router("round_robin", lambda *a: 0)
"""


def test_registry_roundtrip_positive():
    f = analyze_source(REGISTRY_POS, rules=["registry-roundtrip"])
    assert rules_of(f) == {"registry-roundtrip"}
    assert "definitely-not-a-registered-router" in f[0].message


def test_registry_roundtrip_negative():
    assert analyze_source(REGISTRY_NEG, rules=["registry-roundtrip"]) == []


def test_live_registries_are_clean():
    # runtime half on the real repo: constructible + JSON-round-trippable
    assert check_registries() == []


# ---------------------------------------------------------------------------
# kernel-interpret / kernel-vmem
# ---------------------------------------------------------------------------

INTERPRET_POS = """
import jax.experimental.pallas as pl

def launch(kernel, x, shape):
    return pl.pallas_call(kernel, out_shape=shape)(x)
"""

INTERPRET_NEG = """
import jax.experimental.pallas as pl

def launch(kernel, x, shape, interpret):
    return pl.pallas_call(kernel, out_shape=shape, interpret=interpret)(x)
"""


def test_kernel_interpret_positive():
    f = analyze_source(INTERPRET_POS, rules=["kernel-interpret"])
    assert rules_of(f) == {"kernel-interpret"}


def test_kernel_interpret_negative():
    assert analyze_source(INTERPRET_NEG, rules=["kernel-interpret"]) == []


def test_vmem_audit_default_budget():
    # under the real 16 MiB budget only the known 400x400 head-fused
    # limitation fires (carried in the committed baseline, not fixed)
    findings = audit_vmem_budgets()
    assert all("400x400" in f.message for f in findings)


def test_vmem_audit_tiny_budget_fires():
    findings = audit_vmem_budgets(vmem_limit=1024)
    assert findings and rules_of(findings) == {"kernel-vmem"}


# ---------------------------------------------------------------------------
# broad-except
# ---------------------------------------------------------------------------

EXCEPT_POS = """
def f():
    try:
        g()
    except Exception:
        pass
"""

EXCEPT_NEG_NARROW = """
def f():
    try:
        g()
    except (ValueError, KeyError):
        pass
"""

EXCEPT_NEG_RERAISE = """
def f():
    try:
        g()
    except Exception:
        cleanup()
        raise
"""

EXCEPT_SUPPRESSED = """
def f():
    try:
        g()
    except Exception:  # repro: allow(broad-except) -- probe: any failure means unsupported
        pass
"""

EXCEPT_NO_JUSTIFICATION = """
def f():
    try:
        g()
    except Exception:  # repro: allow(broad-except)
        pass
"""


def test_broad_except_positive():
    f = analyze_source(EXCEPT_POS, rules=["broad-except"])
    assert rules_of(f) == {"broad-except"}


def test_broad_except_narrow_negative():
    assert analyze_source(EXCEPT_NEG_NARROW, rules=["broad-except"]) == []


def test_broad_except_reraise_negative():
    assert analyze_source(EXCEPT_NEG_RERAISE, rules=["broad-except"]) == []


def test_broad_except_suppressed_with_justification():
    f = analyze_source(EXCEPT_SUPPRESSED, rules=["broad-except"])
    assert len(f) == 1 and f[0].suppressed
    assert "unsupported" in f[0].justification


def test_suppression_without_justification_does_not_suppress():
    f = analyze_source(EXCEPT_NO_JUSTIFICATION, rules=["broad-except"])
    assert rules_of(f) == {"broad-except", "suppression-justification"}
    assert all(not fi.suppressed for fi in f)


def test_allow_example_in_docstring_is_not_a_waiver():
    src = '"""# repro: allow(broad-except) -- not a real comment"""\n' + EXCEPT_POS
    f = analyze_source(src, rules=["broad-except"])
    assert len(f) == 1 and not f[0].suppressed


# ---------------------------------------------------------------------------
# syntax
# ---------------------------------------------------------------------------

def test_syntax_positive():
    f = analyze_source("def f(:\n", rules=[])
    assert rules_of(f) == {"syntax"}


def test_syntax_negative():
    assert analyze_source("x = 1\n", rules=[]) == []


# ---------------------------------------------------------------------------
# baseline machinery
# ---------------------------------------------------------------------------

def test_baseline_roundtrip_and_diff(tmp_path):
    old = analyze_source(EXCEPT_POS, rules=["broad-except"])
    path = tmp_path / "baseline.json"
    save_baseline(path, old, [])
    baseline = load_baseline(path)

    # same findings -> nothing new; a new finding is detected; removing
    # the old one leaves its fingerprint stale
    new_src = EXCEPT_POS + "\n\ndef h():\n    try:\n        g()\n    except Exception:\n        return None\n"
    live = analyze_source(new_src, rules=["broad-except"])
    new, known, stale = diff_against_baseline(live, baseline)
    assert len(known) == 1 and len(new) == 1 and stale == []

    new2, known2, stale2 = diff_against_baseline([], baseline)
    assert new2 == [] and known2 == [] and len(stale2) == 1


def test_baseline_unknown_version_refused(tmp_path):
    path = tmp_path / "baseline.json"
    path.write_text(json.dumps({"version": 99, "findings": []}))
    with pytest.raises(ValueError, match="version"):
        load_baseline(path)


def test_baseline_unjustified_suppression_is_a_problem(tmp_path):
    path = tmp_path / "baseline.json"
    save_baseline(
        path,
        [],
        [
            Suppression("a.py", 3, ("broad-except",), ""),
            Suppression("b.py", 7, ("rng-reset",), "real reason"),
        ],
    )
    problems = baseline_problems(load_baseline(path))
    assert len(problems) == 1 and "a.py:3" in problems[0]


def test_committed_baseline_has_only_justified_suppressions():
    baseline = load_baseline(REPO_ROOT / "analysis_baseline.json")
    assert baseline_problems(baseline) == []


# ---------------------------------------------------------------------------
# every registered rule is exercised above
# ---------------------------------------------------------------------------

def test_all_rules_have_fixture_coverage():
    covered = {
        "timing-warmup",
        "timing-monotonic-accum",
        "rng-reset",
        "rng-unseeded",
        "socket-shutdown",
        "thread-lifecycle",
        "schema-version",
        "registry-roundtrip",
        "kernel-interpret",
        "kernel-vmem",
        "broad-except",
        "syntax",
        "suppression-justification",
    }
    assert set(rule_names()) == covered


# ---------------------------------------------------------------------------
# schema forward-compat (companion runtime check for rule 4)
# ---------------------------------------------------------------------------

def test_schema_version_error_is_typed_and_a_valueerror():
    assert issubclass(SchemaVersionError, ValueError)


def test_deployment_config_unknown_version_raises():
    from repro.deploy import DeploymentConfig

    d = DeploymentConfig.standard().to_dict()
    d["version"] = 99
    with pytest.raises(SchemaVersionError, match="version"):
        DeploymentConfig.from_dict(d)


def test_scenario_unknown_version_raises():
    from repro.serving.scenario import SCENARIOS, Scenario

    d = next(iter(SCENARIOS.values())).to_dict()
    d["version"] = 99
    with pytest.raises(SchemaVersionError, match="version"):
        Scenario.from_dict(d)


def test_tuned_plan_unknown_version_raises():
    from repro.core.tuning import TunedPlan

    d = TunedPlan(backend="fused", tile_h=8, micro_batch=4).to_dict()
    d["version"] = 99
    with pytest.raises(SchemaVersionError, match="version"):
        TunedPlan.from_dict(d)


def test_shaping_config_unknown_version_raises():
    from repro.serving.realfleet import ShapingConfig

    d = ShapingConfig(rate_mbps=2.0).to_dict()
    assert d["version"] == 1
    d["version"] = 99
    with pytest.raises(SchemaVersionError, match="version"):
        ShapingConfig.from_dict(d)


def test_tuned_plan_unknown_field_still_raises():
    # unknown fields must not silently drop (pre-existing contract)
    from repro.core.tuning import TunedPlan

    d = TunedPlan(backend="fused", tile_h=8, micro_batch=4).to_dict()
    d["mystery"] = 1
    with pytest.raises(ValueError, match="unknown"):
        TunedPlan.from_dict(d)


# ---------------------------------------------------------------------------
# the repo itself passes --strict against the committed baseline
# ---------------------------------------------------------------------------

def test_repo_is_strict_clean(monkeypatch, capsys):
    from repro.analysis.__main__ import main

    monkeypatch.chdir(REPO_ROOT)
    assert main(["--strict"]) == 0
