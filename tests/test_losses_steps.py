"""Vocab-sharded CE loss correctness + step-builder lowering on a host
mesh (the production-mesh path is exercised by launch.dryrun)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.nn.losses import softmax_cross_entropy


def test_ce_matches_naive():
    key = jax.random.PRNGKey(0)
    logits = jax.random.normal(key, (2, 8, 64))
    targets = jax.random.randint(key, (2, 8), 0, 64)
    got = softmax_cross_entropy(logits, targets)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], -1)[..., 0]
    np.testing.assert_allclose(got, logz - gold, atol=1e-5, rtol=1e-5)


def test_ce_grad_matches_naive():
    key = jax.random.PRNGKey(1)
    logits = jax.random.normal(key, (2, 4, 32))
    targets = jax.random.randint(key, (2, 4), 0, 32)
    g1 = jax.grad(lambda l: softmax_cross_entropy(l, targets).mean())(logits)

    def naive(l):
        lz = jax.nn.logsumexp(l, axis=-1)
        gold = jnp.take_along_axis(l, targets[..., None], -1)[..., 0]
        return (lz - gold).mean()

    g2 = jax.grad(naive)(logits)
    np.testing.assert_allclose(g1, g2, atol=1e-5, rtol=1e-5)


def test_ce_bf16_logits():
    key = jax.random.PRNGKey(2)
    logits = jax.random.normal(key, (1, 4, 128)).astype(jnp.bfloat16)
    targets = jax.random.randint(key, (1, 4), 0, 128)
    ce = softmax_cross_entropy(logits, targets)
    assert ce.dtype == jnp.float32
    assert jnp.isfinite(ce).all()


@pytest.mark.slow
def test_step_bundles_lower_on_host_mesh():
    """make_step builds and lowers on a trivial mesh for a reduced-scale
    custom shape — validates the jit/sharding plumbing without the 512-
    device production mesh."""
    import repro.launch.steps as steps
    from repro.configs import SHAPES
    from repro.launch.mesh import make_host_mesh
    from repro.models.config import ShapeConfig

    mesh = make_host_mesh((1, 1), ("data", "model"))
    tiny = {
        "train_4k": ShapeConfig("train_4k", 64, 2, "train"),
        "prefill_32k": ShapeConfig("prefill_32k", 64, 2, "prefill"),
        "decode_32k": ShapeConfig("decode_32k", 64, 2, "decode"),
        "long_500k": ShapeConfig("long_500k", 256, 1, "decode"),
    }
    orig = dict(SHAPES)
    SHAPES.update(tiny)
    try:
        for shape_id in ("train_4k", "decode_32k"):
            b = steps.make_step("qwen3-0.6b", shape_id, mesh,
                                overrides={"n_layers": 2, "n_pattern": 2,
                                           "d_model": 64, "n_heads": 2,
                                           "n_kv_heads": 1, "head_dim": 32,
                                           "d_ff": 128, "vocab": 256,
                                           "dtype": "float32"})
            lowered = b.lower(mesh)
            compiled = lowered.compile()
            assert compiled.cost_analysis() is not None
    finally:
        SHAPES.clear()
        SHAPES.update(orig)
