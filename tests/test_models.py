"""Per-architecture smoke tests (reduced variants: <=2 layers,
d_model<=256, <=4 experts) — one forward/train step on CPU, output shapes
+ no NaNs; decode-vs-forward consistency for the decoder families."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, SHAPES
from repro.models.registry import (abstract_params, build_model, get_model,
                                   input_specs, text_len)

ARCH_IDS = sorted(ARCHS)


def _batch(cfg, key, B=2, S=32):
    batch = {"tokens": jax.random.randint(key, (B, S), 3, cfg.vocab)}
    if cfg.family in ("vlm", "audio"):
        batch["frontend_embeds"] = jax.random.normal(
            key, (B, cfg.n_frontend_tokens, cfg.d_model)) * 0.02
    return batch


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_reduced_smoke_forward_and_train_step(arch_id):
    cfg, model = get_model(arch_id, reduced=True)
    assert cfg.n_layers <= 2 and cfg.d_model <= 512
    if cfg.moe:
        assert cfg.moe.n_experts <= 4
    key = jax.random.PRNGKey(0)
    params = model.init(key)
    batch = _batch(cfg, key)

    logits, _ = model.forward(params, batch["tokens"],
                              frontend_embeds=batch.get("frontend_embeds"))
    S_total = batch["tokens"].shape[1] + (
        cfg.n_frontend_tokens if cfg.family == "vlm" else 0)
    assert logits.shape == (2, S_total, cfg.vocab)
    assert not jnp.isnan(logits).any()

    # one train step
    def loss_fn(p):
        return model.loss(p, batch, remat=False)[0]

    loss, grads = jax.value_and_grad(loss_fn)(params)
    assert jnp.isfinite(loss)
    gnorm = sum(float(jnp.abs(g).sum()) for g in jax.tree.leaves(grads))
    assert np.isfinite(gnorm) and gnorm > 0


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_reduced_decode_step(arch_id):
    cfg, model = get_model(arch_id, reduced=True)
    key = jax.random.PRNGKey(1)
    params = model.init(key)
    B, S = 2, 16
    caches = model.init_cache(B, S, jnp.float32)
    if cfg.family == "audio":
        fe = jax.random.normal(key, (B, cfg.n_frontend_tokens,
                                     cfg.d_model)) * 0.02
        enc = model.encode(params, fe)
        caches = model.prefill_cross_cache(params, enc, caches)
    tok = jax.random.randint(key, (B, 1), 3, cfg.vocab)
    logits, new_caches = model.decode_step(params, tok, caches,
                                           jnp.int32(0))
    assert logits.shape[0] == B and logits.shape[-1] == cfg.vocab
    assert not jnp.isnan(logits).any()
    assert jax.tree.structure(new_caches) == jax.tree.structure(caches)


@pytest.mark.parametrize("arch_id", ["qwen3-0.6b", "mamba2-130m",
                                     "recurrentgemma-9b"])
def test_decode_matches_forward_end_to_end(arch_id):
    """Greedy decode logits == teacher-forced forward logits, per family
    (dense / ssm / hybrid)."""
    cfg, model = get_model(arch_id, reduced=True)
    key = jax.random.PRNGKey(2)
    params = model.init(key)
    B, S = 1, 16        # multiple of the reduced SSD chunk (8)
    tokens = jax.random.randint(key, (B, S), 3, cfg.vocab)
    full, _ = model.forward(params, tokens)
    caches = model.init_cache(B, S, jnp.float32)
    outs = []
    for t in range(S):
        lg, caches = model.decode_step(params, tokens[:, t:t + 1], caches,
                                       jnp.int32(t))
        outs.append(lg)
    dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec, np.float32),
                               np.asarray(full, np.float32),
                               atol=2e-2, rtol=2e-2)


@pytest.mark.parametrize("arch_id", ARCH_IDS)
@pytest.mark.parametrize("shape_id", sorted(SHAPES))
def test_input_specs_abstract(arch_id, shape_id):
    """input_specs never allocates and matches the assigned shapes."""
    spec = input_specs(arch_id, shape_id)
    shape = SHAPES[shape_id]
    cfg = ARCHS[arch_id]
    if shape.kind in ("train", "prefill"):
        t = spec["batch"]["tokens"]
        assert t.shape == (shape.global_batch, text_len(cfg, shape))
        assert t.dtype == jnp.int32
        if cfg.family in ("vlm", "audio"):
            fe = spec["batch"]["frontend_embeds"]
            assert fe.shape == (shape.global_batch, cfg.n_frontend_tokens,
                                cfg.d_model)
    else:
        assert spec["token"].shape == (shape.global_batch, 1)
        leaves = jax.tree.leaves(spec["caches"])
        assert leaves, "decode must carry a cache"
        assert all(isinstance(l, jax.ShapeDtypeStruct) for l in leaves)


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_exact_assigned_dims(arch_id):
    """The full config matches the assignment table verbatim."""
    expected = {
        "qwen3-0.6b": (28, 1024, 16, 8, 3072, 151936),
        "recurrentgemma-9b": (38, 4096, 16, 1, 12288, 256000),
        "qwen2.5-14b": (48, 5120, 40, 8, 13824, 152064),
        "llama4-scout-17b-a16e": (48, 5120, 40, 8, 8192, 202048),
        "mamba2-130m": (24, 768, 1, 1, 0, 50280),
        "whisper-medium": (24, 1024, 16, 16, 4096, 51865),
        "minitron-8b": (32, 4096, 32, 8, 16384, 256000),
        "qwen2-moe-a2.7b": (24, 2048, 16, 16, 1408, 151936),
        "llava-next-mistral-7b": (32, 4096, 32, 8, 14336, 32000),
        "llama3-8b": (32, 4096, 32, 8, 14336, 128256),
    }[arch_id]
    c = ARCHS[arch_id]
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab) == expected


def test_moe_configs():
    l4 = ARCHS["llama4-scout-17b-a16e"].moe
    assert (l4.n_experts, l4.top_k) == (16, 1)
    q2 = ARCHS["qwen2-moe-a2.7b"].moe
    assert (q2.n_experts, q2.top_k, q2.n_shared_experts) == (60, 4, 4)


def test_abstract_params_no_alloc():
    cfg, model = get_model("llama3-8b")        # FULL 8B config, no alloc
    p = abstract_params(model)
    n = sum(np.prod(l.shape) for l in jax.tree.leaves(p))
    assert abs(n - cfg.param_count()) / cfg.param_count() < 0.02
